"""Declarative parameter system.

Every model describes its weights as a flat ``{path: ParamSpec}`` dict.
From the specs we derive, without ever materialising full-scale tensors:

  * ``init_params``      — real arrays (reduced configs, CPU tests),
  * ``abstract_params``  — ShapeDtypeStructs (multi-pod dry-run lowering),
  * ``param_pspecs``     — PartitionSpecs via the logical sharding rules.

Block (per-layer) parameters carry a leading ``layers`` axis and are
consumed with ``lax.scan`` over layers, keeping HLO size O(1) in depth —
essential for compiling 48-80 layer models for 512 devices on the CPU
container.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                    # logical axis names (str or None) per dim
    init: str = "normal"           # normal | zeros | ones | embed
    dtype: Any = jnp.float32
    scale: float | None = None     # stddev override for "normal"/"embed"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple) -> int:
    # weights here are (in, out)-style matrices or stacked (L, in, out)
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1])) if len(shape) == 2 else int(
        np.prod(shape[1:-1]))


def init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "normal":
        std = spec.scale if spec.scale is not None else _fan_in(
            spec.shape) ** -0.5
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs: dict, key) -> dict:
    """Materialise real parameters (use only for reduced configs)."""
    paths = sorted(specs)
    keys = jax.random.split(key, len(paths))
    return {p: init_one(specs[p], k) for p, k in zip(paths, keys)}


def abstract_params(specs: dict, dtype_override=None) -> dict:
    """ShapeDtypeStructs for .lower() — no allocation."""
    return {p: jax.ShapeDtypeStruct(s.shape, dtype_override or s.dtype)
            for p, s in specs.items()}


def param_count(specs: dict) -> int:
    return sum(int(np.prod(s.shape)) for s in specs.values())


def subtree(params: dict, prefix: str) -> dict:
    """Sub-dict of params under ``prefix/`` with the prefix stripped."""
    pre = prefix + "/"
    return {p[len(pre):]: v for p, v in params.items() if p.startswith(pre)}
