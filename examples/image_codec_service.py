"""Batched image-compression service — the paper's application deployed as
a throughput pipeline on the fused Pallas codec kernel.

A batch of images arrives, the service compresses each at a target quality,
reports PSNR / ratio / throughput, and (as in the paper's pipeline) returns
the reconstructed images.

    PYTHONPATH=src python examples/image_codec_service.py --batch 8
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import images, metrics, quant
from repro.kernels.fused_codec import fused_codec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--quality", type=int, default=50)
    args = ap.parse_args()

    # mixed workload: half portraits, half street scenes
    batch = np.stack(
        [images.lena_like(args.size, args.size, seed=i) if i % 2 == 0
         else images.cablecar_like(args.size, args.size, seed=i)
         for i in range(args.batch)])
    batch_j = jnp.asarray(batch)

    t0 = time.monotonic()
    rec, qc = fused_codec(batch_j, quality=args.quality)
    rec.block_until_ready()
    dt = time.monotonic() - t0

    mpix = args.batch * args.size * args.size / 1e6
    print(f"compressed {args.batch} x {args.size}x{args.size} "
          f"({mpix:.1f} MPix) in {dt:.2f}s -> {mpix/dt:.1f} MPix/s "
          f"(interpret-mode kernel on CPU; compiled on TPU)")
    for i in range(args.batch):
        p = float(metrics.psnr(batch_j[i], rec[i]))
        ratio = float(quant.compression_ratio(
            jnp.asarray(qc[i]).reshape(args.size // 8, 8,
                                       args.size // 8, 8).swapaxes(1, 2),
            args.size, args.size))
        kind = "lena" if i % 2 == 0 else "cablecar"
        print(f"  img{i} ({kind:8s}): {p:6.2f} dB, {ratio:5.1f}x")


if __name__ == "__main__":
    main()
