"""Batched image-compression service — the paper's application deployed
through the multi-device codec engine.

A batch of images arrives (optionally mixed sizes, as a real service would
see), the engine buckets + pads them, shards the batch over every local
device, compresses at a target quality and reports PSNR, *measured*
entropy-coded bytes per image, and throughput.  On TPU the roundtrip runs
the one-pass fused Pallas kernel; on CPU it runs the batch-first core
codec, bit-identical to the single-image API.

    PYTHONPATH=src python examples/image_codec_service.py --batch 8
    PYTHONPATH=src python examples/image_codec_service.py --batch 8 --ragged
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import images, metrics
from repro.serve import codec_engine


def make_workload(batch: int, size: int, ragged: bool):
    """Half portraits, half street scenes; ragged mode mixes sizes."""
    out = []
    for i in range(batch):
        gen = images.lena_like if i % 2 == 0 else images.cablecar_like
        if ragged:
            h = size - 16 * (i % 3)          # e.g. 256 / 240 / 224
            w = size - 10 * (i % 4)          # non-multiples of 8 included
        else:
            h = w = size
        out.append(gen(h, w, seed=i))
    return out if ragged else np.stack(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--quality", type=int, default=50)
    ap.add_argument("--transform", default="exact",
                    choices=["exact", "loeffler", "cordic"])
    ap.add_argument("--ragged", action="store_true",
                    help="mixed image sizes (exercises shape bucketing)")
    args = ap.parse_args()

    batch = make_workload(args.batch, args.size, args.ragged)

    # warm-up compiles the same staged jits the timed section runs
    warm = codec_engine.compress_batch(batch, args.quality, args.transform)
    jax.block_until_ready(codec_engine.decompress_batch(warm))

    t0 = time.monotonic()
    cb = codec_engine.compress_batch(batch, args.quality, args.transform)
    rec = codec_engine.decompress_batch(cb)
    jax.block_until_ready(rec)
    dt = time.monotonic() - t0
    blobs = cb.to_bytes_list()      # real entropy-coded bytes per image

    imgs = list(batch) if args.ragged else [batch[i]
                                            for i in range(args.batch)]
    mpix = sum(im.shape[0] * im.shape[1] for im in imgs) / 1e6
    print(f"compressed {args.batch} images ({mpix:.1f} MPix) on "
          f"{jax.local_device_count()} {jax.default_backend()} device(s) "
          f"in {dt:.2f}s -> {mpix / dt:.1f} MPix/s, "
          f"{args.batch / dt:.1f} img/s")

    recs = rec if args.ragged else [rec[i] for i in range(args.batch)]
    for i, (im, r, blob) in enumerate(zip(imgs, recs, blobs)):
        p = float(metrics.psnr(jnp.asarray(im), r))
        ratio = im.shape[0] * im.shape[1] / len(blob)   # measured bytes
        kind = "lena" if i % 2 == 0 else "cablecar"
        print(f"  img{i} ({kind:8s} {im.shape[0]:4d}x{im.shape[1]:<4d}): "
              f"{p:6.2f} dB, {len(blob):6d} B, {ratio:5.1f}x")


if __name__ == "__main__":
    main()
