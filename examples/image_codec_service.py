"""Async image-compression service demo — concurrent clients, real SLOs.

Spins up the asyncio :class:`repro.serve.service.CodecService` in front
of the multi-device codec engine and drives it with N closed-loop
clients submitting mixed-size images under per-request deadlines and
per-tenant quality tiers ("gold" keeps its requested quality, "free" is
clamped to quality 40).  The service buckets requests by (shape,
quality), batches adaptively (bucket full / deadline urgent / max-wait
timer), sheds load with explicit rejects when queues fill, and serves
repeated images from its hot-stream cache.

Prints per-tenant outcomes plus the service-side stats: p50/p99
latency, batch-occupancy histogram, reject reasons, cache hits.

    PYTHONPATH=src python examples/image_codec_service.py
    PYTHONPATH=src python examples/image_codec_service.py \
        --clients 8 --requests 12 --deadline-ms 500
"""

import argparse
import asyncio
import collections
import time

import numpy as np

from repro.core import images
from repro.serve.admission import RejectedError, TenantTier
from repro.serve.service import CodecService, ServiceConfig


def make_pool(size: int, variants: int = 6):
    """A small pool of mixed-size test images; reuse produces cache hits."""
    pool = []
    for i in range(variants):
        gen = images.lena_like if i % 2 == 0 else images.cablecar_like
        h = size - 16 * (i % 3)          # e.g. 128 / 112 / 96
        w = size - 10 * (i % 4)
        pool.append(np.asarray(gen(h, w, seed=i)))
    return pool


async def client(svc: CodecService, name: str, tenant: str, pool,
                 requests: int, deadline_s: float, quality: int,
                 rng: np.random.Generator, outcomes: collections.Counter):
    """One closed-loop client: submit, await the outcome, think, repeat."""
    for _ in range(requests):
        img = pool[int(rng.integers(len(pool)))]
        try:
            resp = await svc.submit(img, quality=quality, tenant=tenant,
                                    deadline_s=deadline_s)
            tag = "cache" if resp.cache_hit else f"batch{resp.batch_size}"
            outcomes[f"{tenant}:served"] += 1
            outcomes[f"{tenant}:bytes"] += len(resp.payload)
            if resp.deadline_missed:
                outcomes[f"{tenant}:late"] += 1
            print(f"  {name}: {img.shape[0]}x{img.shape[1]} q{resp.quality}"
                  f" -> {len(resp.payload)} B ({tag},"
                  f" {resp.latency_s * 1e3:.1f} ms)")
        except RejectedError as exc:
            outcomes[f"{tenant}:rejected:{exc.reason}"] += 1
            print(f"  {name}: rejected ({exc.reason})")
        await asyncio.sleep(float(rng.uniform(0, 0.01)))   # think time


async def run(args):
    pool = make_pool(args.size)
    cfg = ServiceConfig(
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1e3,
        max_queue_depth=4 * args.max_batch,
        default_deadline_s=args.deadline_ms / 1e3,
        tenants={"gold": TenantTier(max_quality=100),
                 "free": TenantTier(max_quality=40)},
    )
    outcomes = collections.Counter()
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    async with CodecService(cfg) as svc:
        # warm the engine once so client latencies reflect steady state
        await svc.submit(pool[0], deadline_s=None)
        tasks = []
        for i in range(args.clients):
            tenant = "gold" if i % 2 == 0 else "free"
            tasks.append(client(
                svc, f"client{i}", tenant, pool, args.requests,
                args.deadline_ms / 1e3, args.quality,
                np.random.default_rng(100 + i), outcomes))
        await asyncio.gather(*tasks)
        stats = svc.stats.snapshot()
        cache = svc.cache
    dt = time.monotonic() - t0

    print(f"\n{args.clients} clients x {args.requests} requests "
          f"in {dt:.2f}s")
    for tenant in ("gold", "free"):
        served = outcomes[f"{tenant}:served"]
        if not served:
            continue
        print(f"  {tenant}: {served} served "
              f"({outcomes[f'{tenant}:late']} late), "
              f"{outcomes[f'{tenant}:bytes'] / served:.0f} B avg")
    print(f"  latency p50/p99: {stats['p50_latency_s'] * 1e3:.1f} / "
          f"{stats['p99_latency_s'] * 1e3:.1f} ms")
    print(f"  batch occupancy: {stats['occupancy']}")
    print(f"  rejected: {stats['rejected'] or 'none'}; "
          f"cache hits: {cache.hits}/{cache.hits + cache.misses}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per client")
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--quality", type=int, default=75,
                    help="requested quality (tiers may clamp)")
    ap.add_argument("--deadline-ms", type=float, default=1000.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    args = ap.parse_args()
    asyncio.run(run(args))


if __name__ == "__main__":
    main()
