"""Compress a file to disk: encode/decode ``.dctz`` streams from the CLI.

The on-disk artifact is the real entropy-coded container
(``repro.core.entropy``, spec in docs/bitstream.md) — measured bytes,
not an in-memory coefficient array.  Grayscale images travel as binary
PGM (P5) or ``.npy``; ``demo:NAME:HxW`` synthesises the repo's Lena /
Cable-car stand-ins so the example runs with no input files at all.

    PYTHONPATH=src python examples/dctz_cli.py encode demo:lena:512x512 \
        /tmp/lena.dctz --quality 50
    PYTHONPATH=src python examples/dctz_cli.py info   /tmp/lena.dctz
    PYTHONPATH=src python examples/dctz_cli.py decode /tmp/lena.dctz \
        /tmp/lena_rec.pgm --verify-crc

``info`` and ``decode`` exit nonzero with a one-line ``error:``
diagnostic on a malformed stream (truncation, trailing bytes, CRC
mismatch, bad tables) instead of a traceback, so shell pipelines can
gate on corruption; ``decode --verify-crc`` checks the container CRC
explicitly before parsing and names the stored vs computed digests on
mismatch.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

import numpy as np

from repro.core import entropy, images, metrics


def _timed(fn, *args):
    """(result, wall seconds) with one untimed warmup call (absorbs jit
    compilation so --time reports the steady-state the benches see)."""
    fn(*args)
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def read_gray(spec: str) -> np.ndarray:
    """Load (H, W) uint8 from a .pgm/.npy path or a demo:NAME:HxW spec."""
    if spec.startswith("demo:"):
        _, name, size = spec.split(":")
        h, w = (int(s) for s in size.split("x"))
        fn = {"lena": images.lena_like,
              "cablecar": images.cablecar_like}[name]
        return fn(h, w)
    path = pathlib.Path(spec)
    if path.suffix == ".npy":
        arr = np.load(path)
        if arr.ndim != 2:
            raise SystemExit(f"{path}: expected a 2-D grayscale array, "
                             f"got shape {arr.shape}")
        return arr.astype(np.uint8)
    return _read_pgm(path)


def _read_pgm(path: pathlib.Path) -> np.ndarray:
    data = path.read_bytes()
    fields, pos = [], 0
    while len(fields) < 4:                     # magic, W, H, maxval
        end = min(i for i in (data.find(b" ", pos), data.find(b"\n", pos),
                              data.find(b"\t", pos)) if i != -1)
        tok = data[pos:end]
        if tok.startswith(b"#"):               # comment to end of line
            end = data.find(b"\n", pos)
        elif tok:
            fields.append(tok)
        pos = end + 1
    if fields[0] != b"P5":
        raise SystemExit(f"{path}: only binary PGM (P5) is supported")
    w, h, maxval = (int(f) for f in fields[1:])
    if maxval != 255:
        raise SystemExit(f"{path}: only 8-bit PGM supported")
    return np.frombuffer(data[pos:pos + h * w],
                         np.uint8).reshape(h, w).copy()


def write_gray(path: pathlib.Path, img: np.ndarray) -> None:
    """Write (H, W) uint8 as .npy or binary PGM, by extension."""
    if path.suffix == ".npy":
        np.save(path, img)
        return
    h, w = img.shape
    path.write_bytes(b"P5\n%d %d\n255\n" % (w, h)
                     + np.asarray(img, np.uint8).tobytes())


def cmd_encode(args) -> int:
    img = read_gray(args.input)
    h, w = img.shape
    enc = lambda: entropy.encode_image(img, args.quality, args.transform,
                                       tables=args.tables)
    if args.time:
        blob, dt = _timed(enc)
        print(f"encode: {dt * 1e3:.2f} ms "
              f"({h * w / 1e6 / dt:.1f} MB/s of pixels, "
              f"{1 / dt:.1f} img/s)")
    else:
        blob = enc()
    pathlib.Path(args.output).write_bytes(blob)
    bpp = len(blob) * 8 / (h * w)
    print(f"{args.output}: {len(blob)} bytes for {h}x{w} "
          f"({bpp:.3f} bits/px, {8 / bpp:.1f}x vs 8-bit raw)")
    return 0


def _stream_error(path: str, exc: Exception) -> int:
    """One-line diagnostic on stderr for a malformed stream, exit 1."""
    kind = ("truncated stream" if isinstance(exc, entropy.TruncatedStream)
            else "bad stream")
    print(f"error: {path}: {kind}: {exc}", file=sys.stderr)
    return 1


def cmd_decode(args) -> int:
    blob = pathlib.Path(args.input).read_bytes()
    if args.verify_crc:
        try:
            hdr = entropy.read_header(blob)
            if not entropy.verify_crc(blob):
                return _stream_error(
                    args.input, entropy.BitstreamError(
                        f"CRC mismatch (header says "
                        f"{hdr['crc32']:#010x})"))
        except (entropy.BitstreamError, entropy.TruncatedStream) as exc:
            return _stream_error(args.input, exc)
        print(f"{args.input}: crc ok")
    try:
        if args.time:
            rec, dt = _timed(entropy.decode_image, blob, args.mode)
            rec = np.asarray(rec)
            h, w = rec.shape
            print(f"decode: {dt * 1e3:.2f} ms "
                  f"({h * w / 1e6 / dt:.1f} MB/s of pixels, "
                  f"{1 / dt:.1f} img/s)")
        else:
            rec = np.asarray(entropy.decode_image(blob, mode=args.mode))
    except (entropy.BitstreamError, entropy.TruncatedStream) as exc:
        return _stream_error(args.input, exc)
    write_gray(pathlib.Path(args.output), rec)
    print(f"{args.output}: {rec.shape[0]}x{rec.shape[1]} reconstructed")
    if args.original:
        orig = read_gray(args.original)
        print(f"PSNR vs {args.original}: "
              f"{float(metrics.psnr(orig, rec)):.2f} dB")
    return 0


def _table_desc(table_id: int) -> str:
    """Human name for a container table id (0 embeds, >= 1 is shared)."""
    return "embedded" if table_id == 0 else f"shared#{table_id}"


def cmd_info(args) -> int:
    data = pathlib.Path(args.input).read_bytes()
    try:
        hdr = entropy.read_header(data)
        crc_ok = entropy.verify_crc(data)
    except (entropy.BitstreamError, entropy.TruncatedStream) as exc:
        return _stream_error(args.input, exc)
    px = hdr["height"] * hdr["width"]
    print(f"{args.input}: DCTZ v{hdr['version']} "
          f"{hdr['height']}x{hdr['width']} quality={hdr['quality']} "
          f"transform={hdr['transform']} "
          f"tables=(dc:{_table_desc(hdr['dc_table_id'])},"
          f"ac:{_table_desc(hdr['ac_table_id'])}) "
          f"crc={'ok' if crc_ok else 'MISMATCH'} "
          f"payload={hdr['payload_nbytes']}B "
          f"total={len(data)}B ({len(data) * 8 / px:.3f} bits/px)")
    if not crc_ok:
        return _stream_error(args.input, entropy.BitstreamError(
            f"CRC mismatch (header says {hdr['crc32']:#010x})"))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    enc = sub.add_parser("encode", help="image file -> .dctz")
    enc.add_argument("input", help=".pgm/.npy path or demo:NAME:HxW")
    enc.add_argument("output", help=".dctz output path")
    enc.add_argument("--quality", type=int, default=50)
    enc.add_argument("--transform", default="exact",
                     choices=["exact", "cordic", "loeffler"])
    enc.add_argument("--tables", default="auto",
                     choices=["auto", "embedded", "shared"],
                     help="Huffman table policy: auto picks shared "
                          "well-known tables (container v2) when they "
                          "beat the embedded-table cost; embedded "
                          "forces the v1 layout")
    enc.add_argument("--time", action="store_true",
                     help="print encode wall time and MB/s (one warmup "
                          "call first, so jit compilation is excluded)")
    enc.set_defaults(fn=cmd_encode)

    dec = sub.add_parser("decode", help=".dctz -> image file")
    dec.add_argument("input", help=".dctz path")
    dec.add_argument("output", help=".pgm/.npy output path")
    dec.add_argument("--mode", default="standard",
                     choices=["standard", "matched"])
    dec.add_argument("--original", default=None,
                     help="optional original image to PSNR against")
    dec.add_argument("--verify-crc", action="store_true",
                     help="check the container CRC before parsing and "
                          "fail with the stored vs computed digests on "
                          "mismatch")
    dec.add_argument("--time", action="store_true",
                     help="print decode wall time and MB/s (one warmup "
                          "call first, so jit compilation is excluded)")
    dec.set_defaults(fn=cmd_decode)

    info = sub.add_parser("info", help="print a .dctz header")
    info.add_argument("input", help=".dctz path")
    info.set_defaults(fn=cmd_info)

    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
