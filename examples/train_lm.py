"""End-to-end training driver: train a smollm-family LM on the synthetic
Markov corpus with checkpointing and (optionally) DCT gradient compression,
then compare the two loss curves.

Default size is CPU-friendly (~5M params, 150 steps, a few minutes).
``--scale 100m --steps 300`` reproduces the brief's ~100M-for-a-few-hundred-
steps run on real hardware (on this CPU container it is hours, not run by
default — EXPERIMENTS.md records a mid-scale run).

    PYTHONPATH=src python examples/train_lm.py --steps 150 --compare-compress
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import registry as R
from repro.data.synth import DataConfig, make_batch_fn
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import GradCompressConfig
from repro.train.step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig

SCALES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "5m": (4, 256, 4, 2, 1024, 2048, 128, 8),
    "25m": (8, 512, 8, 4, 2048, 8192, 256, 8),
    "100m": (12, 768, 12, 4, 3072, 32768, 512, 16),
}


def build(scale: str):
    ll, d, h, kv, ff, v, s, b = SCALES[scale]
    cfg = R.reduced("smollm-360m", n_layers=ll, d_model=d, n_heads=h,
                    n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab_size=v)
    data = DataConfig(vocab_size=v, seq_len=s, global_batch=b, seed=0)
    return cfg, data


def run_one(cfg, data, steps, compress, ckpt_dir=None, label=""):
    gc = GradCompressConfig(enabled=compress, keep=16, min_size=4096)
    tr = Trainer(
        cfg,
        AdamWConfig(lr_peak=1e-3, warmup_steps=max(steps // 20, 5),
                    decay_steps=steps),
        TrainerConfig(total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=50,
                      log_every=max(steps // 10, 1)),
        make_batch_fn(data),
        step_cfg=TrainStepConfig(grad_compress=gc))
    print(f"--- {label}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps, compress={compress}")
    return tr.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="5m", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--compare-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg, data = build(args.scale)
    h_base = run_one(cfg, data, args.steps, False, args.ckpt_dir, "baseline")
    print(f"baseline   loss: {h_base[0]['loss']:.4f} -> "
          f"{h_base[-1]['loss']:.4f}")

    if args.compare_compress:
        h_comp = run_one(cfg, data, args.steps, True, None,
                         "dct-compressed grads (keep=16/64, 12.8x wire)")
        print(f"compressed loss: {h_comp[0]['loss']:.4f} -> "
              f"{h_comp[-1]['loss']:.4f}")
        gap = h_comp[-1]["loss"] - h_base[-1]["loss"]
        print(f"convergence gap at step {args.steps}: {gap:+.4f} "
              f"(keep={16}/64 => 12.8x fewer wire bytes; error feedback "
              f"shrinks the gap over longer horizons)")


if __name__ == "__main__":
    main()
