"""Quickstart: the paper's experiment in 60 seconds.

Compresses synthetic Lena/Cable-car stand-ins with the exact DCT and the
Cordic-based Loeffler DCT, reproducing the structure of the paper's
Tables 3-4 (PSNR) and the fused-kernel codec path.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import codec, images, metrics
from repro.kernels.fused_codec import fused_codec


def psnr_table(name, gen, sizes):
    print(f"\n=== {name}: PSNR (dB), quality=50 — paper Tables 3/4 ===")
    print(f"{'size':>12s} {'DCT':>10s} {'Cordic-Loeffler':>16s} {'gap':>6s}")
    for (h, w) in sizes:
        img = gen(h, w)
        _, p_dct = codec.roundtrip(img, 50, "exact")
        _, p_cor = codec.roundtrip(img, 50, "cordic")
        print(f"{h:>5d}x{w:<6d} {p_dct:>10.3f} {p_cor:>16.3f} "
              f"{p_dct - p_cor:>6.2f}")


def main():
    psnr_table("Lena", images.lena_like, [(200, 200), (512, 512)])
    psnr_table("Cable-car", images.cablecar_like,
               [(320, 288), (544, 512)])

    print("\n=== fused Pallas codec kernel (DCT+quant+IDCT, one pass) ===")
    img = images.lena_like(256, 256)
    rec, qc = fused_codec(img, quality=50)
    c = codec.compress(img, 50)
    print(f"PSNR: {float(metrics.psnr(jnp.asarray(img), rec)):.2f} dB | "
          f"compression ratio ~{c.compression_ratio():.1f}x | "
          f"nonzero coeffs {int((qc != 0).sum())}/{qc.size}")

    print("\n=== quality sweep (exact DCT, Lena 256x256) ===")
    for q in (10, 30, 50, 70, 90):
        _, p = codec.roundtrip(img, q, "exact")
        ratio = codec.compress(img, q).compression_ratio()
        print(f"  quality {q:3d}: {p:6.2f} dB, {ratio:5.1f}x")


if __name__ == "__main__":
    main()
