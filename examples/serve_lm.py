"""Batched serving demo: prefill + KV-cached decode, with and without DCT
KV-cache compression.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --max-new 24
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry as R
from repro.models import registry as M
from repro.serve import engine, kv_compress


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--kv-keep", type=int, default=24)
    args = ap.parse_args()

    cfg = R.reduced(args.arch, n_layers=4, d_model=128, vocab_size=1024)
    params = M.init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    max_len = args.prompt_len + args.max_new + 8

    # ---- exact cache -------------------------------------------------------
    cache = M.init_cache(cfg, batch=args.batch, max_len=max_len)
    prefill = engine.make_prefill(cfg)
    step = engine.make_decode_step(cfg)
    logits, cache = prefill(params, prompts, cache)
    nxt = jnp.argmax(logits.astype(jnp.float32), -1).astype(jnp.int32)
    t0 = time.monotonic()
    toks = [nxt]
    for i in range(args.max_new - 1):
        nxt, cache = step(params, nxt[:, None], cache,
                          jnp.asarray(args.prompt_len + i, jnp.int32),
                          jax.random.key(0))
        toks.append(nxt)
    exact = jnp.stack(toks, 1)
    dt = time.monotonic() - t0
    print(f"exact cache:      {args.batch * args.max_new / dt:7.1f} tok/s")

    # ---- DCT-compressed cache ---------------------------------------------
    cache2 = M.init_cache(cfg, batch=args.batch, max_len=max_len)
    _, cache2 = prefill(params, prompts, cache2)
    raw = sum(v.size * v.dtype.itemsize for v in cache2.values())
    ckv, tails = kv_compress.compress_cache(cache2, args.kv_keep,
                                            args.prompt_len)
    comp = kv_compress.wire_bytes(ckv, tails)
    cache2 = kv_compress.reconstruct_cache(ckv, tails)
    logits2, _, _ = M.apply(cfg, params,
                            {"tokens": prompts[:, -1:],
                             "cache_index":
                                 jnp.asarray(args.prompt_len - 1, jnp.int32)},
                            mode="decode", cache=cache2)
    nxt2 = jnp.argmax(logits2[:, -1].astype(jnp.float32), -1)
    toks2 = [nxt2.astype(jnp.int32)]
    for i in range(args.max_new - 1):
        nxt2, cache2 = step(params, toks2[-1][:, None], cache2,
                            jnp.asarray(args.prompt_len + i, jnp.int32),
                            jax.random.key(0))
        toks2.append(nxt2)
    compd = jnp.stack(toks2, 1)
    agree = float((exact == compd).mean())
    print(f"dct cache (keep={args.kv_keep}/64): HBM {raw/comp:.1f}x smaller, "
          f"token agreement {agree:.0%}")
    print("sample exact :", exact[0, :12].tolist())
    print("sample dct   :", compd[0, :12].tolist())


if __name__ == "__main__":
    main()
